"""Line-coverage ratchet for the serving package (src/repro/serve/).

    python -m pytest -q -m "not slow" tests/test_fuzz_serving.py \
        tests/test_expert_library.py --cov=repro.serve \
        --cov-report=json:coverage-serve.json
    python tests/check_coverage.py --report coverage-serve.json \
        --floors COVERAGE_serve.json
    python tests/check_coverage.py --report ... --floors ... --update

Reads a coverage.py JSON report (what ``pytest --cov-report=json:`` under
pytest-cov emits) and compares per-file line coverage of every module
under ``repro/serve/`` — plus the package TOTAL — against the committed
floor file, failing on any file below its floor.  ``--update`` rewrites
the floors from the report (floored to whole percents, so ordinary run-
to-run jitter never manufactures a ratchet).  A missing report file is a
clean skip (exit 0): pytest-cov is a CI-only dependency, local
environments without it must not fail — the floors are enforced where
the report exists.

The gate is one-directional by design: coverage may rise freely (run
``--update`` to bank it); it may not silently fall.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

#: suffix that marks a report entry as belonging to the gated package
PACKAGE = os.path.join("repro", "serve") + os.sep


def serve_coverage(report: dict) -> dict:
    """{module-relative path or "TOTAL": percent covered} for every file
    under repro/serve/ in a coverage.py JSON report."""
    out = {}
    n_cov = n_stmt = 0
    for path, entry in report.get("files", {}).items():
        norm = path.replace("/", os.sep)
        if PACKAGE not in norm:
            continue
        rel = "repro/serve/" + norm.split(PACKAGE, 1)[1].replace(os.sep, "/")
        s = entry["summary"]
        out[rel] = float(s["percent_covered"])
        n_cov += s["covered_lines"]
        n_stmt += s["num_statements"]
    out["TOTAL"] = 100.0 * n_cov / max(n_stmt, 1)
    return out


def check(cov: dict, floors: dict):
    """(failures, lines): every floored entry must be present in the
    report and at or above its floor — a module that vanishes from the
    report (deleted, or no longer imported by the covered tests) is a
    regression, not a pass."""
    failures, lines = [], []
    for name in sorted(floors):
        floor = floors[name]
        got = cov.get(name)
        if got is None:
            failures.append(name)
            lines.append(f"{name:<40} floor {floor:5.1f}%  MISSING from "
                         f"report")
            continue
        bad = got < floor
        lines.append(f"{name:<40} floor {floor:5.1f}%  got {got:5.1f}%  "
                     f"{'BELOW FLOOR' if bad else 'ok'}")
        if bad:
            failures.append(name)
    return failures, lines


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", required=True,
                    help="coverage.py JSON report (pytest --cov-report=json)")
    ap.add_argument("--floors", required=True,
                    help="committed floor file (JSON: {'floors': {...}})")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the floors from the report (whole "
                         "percents, rounded down) instead of gating")
    args = ap.parse_args(argv)

    if not os.path.exists(args.report):
        print(f"coverage: no report at {args.report!r} — pytest-cov not "
              f"installed here; skipping the floor gate (CI enforces it)")
        return 0
    with open(args.report) as f:
        cov = serve_coverage(json.load(f))

    if args.update:
        with open(args.floors) as f:
            doc = json.load(f)
        doc["floors"] = {k: int(math.floor(v)) for k, v in sorted(
            cov.items())}
        with open(args.floors, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"coverage: floors refreshed in {args.floors} "
              f"({len(doc['floors'])} entries)")
        return 0

    with open(args.floors) as f:
        floors = json.load(f)["floors"]
    failures, lines = check(cov, floors)
    print("\n".join(lines))
    if failures:
        print(f"coverage: {len(failures)} file(s) below the committed "
              f"floor — raise test coverage or (after review) refresh "
              f"the floors with --update")
        return 1
    print(f"coverage: {len(floors)} floored entries all at or above floor")
    return 0


if __name__ == "__main__":
    sys.exit(main())
