"""Blockwise attention vs a dense masked reference; decode-cache parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttentionConfig, ModelConfig
from repro.distributed.sharding import ShardCtx
from repro.nn import attention as attn
from repro.nn.layers import Runtime


def dense_ref(q, k, v, causal=True, window=None):
    B, S, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, Dh).astype(np.float32)
    logits = np.einsum("bqngd,bknd->bqngk", qg,
                       np.asarray(k, np.float32)) * Dh ** -0.5
    i = np.arange(S)[:, None]
    j = np.arange(k.shape[1])[None, :]
    mask = np.ones((S, k.shape[1]), bool)
    if causal:
        mask &= i >= j
    if window is not None:
        mask &= (i - j) < window
    logits = np.where(mask[None, :, None, None, :], logits, -1e30)
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    out = np.einsum("bqngk,bknd->bqngd", p, np.asarray(v, np.float32))
    return out.reshape(B, S, H, Dh)


@pytest.mark.parametrize("S,H,KV,window,qb,kb", [
    (64, 4, 4, None, 16, 32), (64, 4, 2, None, 32, 16),
    (64, 4, 1, 16, 16, 32), (128, 8, 2, 32, 32, 64),
    (48, 2, 2, None, 48, 48),
])
def test_blockwise_matches_dense(S, H, KV, window, qb, kb):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, Dh = 2, 16
    q = jax.random.normal(ks[0], (B, S, H, Dh)) * Dh ** -0.25
    k = jax.random.normal(ks[1], (B, S, KV, Dh)) * Dh ** -0.25
    v = jax.random.normal(ks[2], (B, S, KV, Dh))
    # blockwise_attention scales internally by Dh**-0.5; ref does too
    y = attn.blockwise_attention(q * Dh ** 0.25, k * Dh ** 0.25, v,
                                 causal=True, window=window,
                                 q_block=qb, kv_block=kb)
    y_ref = dense_ref(q * Dh ** 0.25, k * Dh ** 0.25, v, causal=True,
                      window=window)
    np.testing.assert_allclose(np.asarray(y, np.float32), y_ref, atol=2e-4,
                               rtol=2e-4)


def _cfg(window=None, S=32):
    return ModelConfig(
        name="t", d_model=32, vocab_size=64, segments=((("attn",), 1),),
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=8,
                                  window=window, q_block=16, kv_block=16),
        dtype="float32", max_seq_len=S)


@pytest.mark.parametrize("window", [None, 8])
def test_decode_matches_prefill(window):
    cfg = _cfg(window)
    rt = Runtime(shard=ShardCtx())
    params = attn.attention_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32))
    y_full, _ = attn.attention_apply(params, x, cfg, rt)
    st = attn.attention_init_state(cfg, B, S, jnp.float32)
    outs = []
    for t in range(S):
        y, st, _ = attn.attention_step(params, x[:, t:t + 1], st,
                                       jnp.int32(t), cfg, rt)
        outs.append(y)
    y_steps = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_steps), np.asarray(y_full),
                               atol=1e-4, rtol=1e-4)


def test_ring_buffer_wraps():
    """Windowed cache must overwrite old slots, never attend beyond window."""
    cfg = _cfg(window=4)
    rt = Runtime(shard=ShardCtx())
    params = attn.attention_init(jax.random.PRNGKey(0), cfg)
    B, S = 1, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32))
    st = attn.attention_init_state(cfg, B, S, jnp.float32)
    assert st["k"].shape[1] == 4          # ring buffer = window slots
    y_full, _ = attn.attention_apply(params, x, cfg, rt)
    for t in range(S):
        y, st, _ = attn.attention_step(params, x[:, t:t + 1], st,
                                       jnp.int32(t), cfg, rt)
    np.testing.assert_allclose(np.asarray(y[:, 0]),
                               np.asarray(y_full[:, -1]), atol=1e-4,
                               rtol=1e-4)


def test_unroll_mode_equivalence():
    """cost_scan / cost_map unrolling is numerically identical."""
    from repro.nn import layers
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 8))
    k = jax.random.normal(ks[1], (1, 64, 2, 8))
    v = jax.random.normal(ks[2], (1, 64, 2, 8))
    y1 = attn.blockwise_attention(q, k, v, q_block=16, kv_block=16)
    layers.set_unroll(True)
    try:
        y2 = attn.blockwise_attention(q, k, v, q_block=16, kv_block=16)
    finally:
        layers.set_unroll(False)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
