"""Decode fast path: the kernels/ops.py impl-resolution registry, the
single-timestep selective-scan and routed-expert Pallas kernels (interpret
mode) vs the kernels/ref.py oracles, and greedy identity of
``EngineConfig(kernels="pallas")`` vs ``"ref"`` through the full engine
across admission/speculative/cache modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from identity import full_cfg as _full_cfg
from repro.distributed.plan import ParallelPlan
from repro.kernels import ops, ref
from repro.kernels.decode_step import (decode_step_fused_pallas,
                                       decode_step_pallas)
from repro.kernels.routed_matmul import routed_matmul_pallas
from repro.models import lm
from repro.nn.layers import dense
from repro.serve import EngineConfig, PrefixCache, Request, ServeEngine
from repro.serve.engine import prefill_chunks  # noqa: F401  (docs parity)


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_registry_resolution_order_and_fallbacks():
    # backend auto on CPU: everything resolves to ref
    assert ops.active_default() is None
    for name in ops.registered_ops():
        assert ops.resolve_impl(name) == "ref"
    # explicit impl wins; off-TPU 'pallas' falls back per-op
    assert ops.resolve_impl("selective_scan", "pallas") == "ref"
    assert ops.resolve_impl("grouped_matmul", "pallas") == "ref"
    assert ops.resolve_impl("selective_scan_step", "pallas") == "fused"
    assert ops.resolve_impl("routed_matmul", "pallas") == "fused"
    # interpret never remaps (it is the CPU test path)
    assert ops.resolve_impl("selective_scan", "interpret") == "interpret"
    # module default fills in for impl=None, explicit still wins
    with ops.default_impl("pallas"):
        assert ops.resolve_impl("routed_matmul") == "fused"
        assert ops.resolve_impl("routed_matmul", "ref") == "ref"
        assert ops.active_default() == "pallas"
    assert ops.active_default() is None
    # nesting restores the outer scope
    with ops.default_impl("ref"):
        with ops.default_impl("pallas"):
            assert ops.active_default() == "pallas"
        assert ops.active_default() == "ref"


def test_registry_rejects_unknown_names():
    with pytest.raises(KeyError):
        ops.resolve_impl("not_an_op")
    with pytest.raises(ValueError):
        ops.resolve_impl("selective_scan", "fused")   # not offered
    with pytest.raises(ValueError):
        ops.set_default_impl("cuda")
    prev = ops.set_default_impl("ref")
    assert prev is None
    assert ops.set_default_impl(None) == "ref"


def test_legacy_impl_kwarg_still_works():
    """The pre-registry per-op ``impl=`` signatures are a working shim."""
    u = jnp.ones((1, 8, 4))
    dt = jnp.full((1, 8, 4), 0.1)
    A = -jnp.ones((4, 2))
    Bm = jnp.ones((1, 8, 2))
    Cm = jnp.ones((1, 8, 2))
    y_ref = ops.selective_scan(u, dt, A, Bm, Cm, chunk=4, impl="ref")
    y_int = ops.selective_scan(u, dt, A, Bm, Cm, chunk=4, impl="interpret")
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)
    # the deprecated module-level ``_resolve`` alias is gone for good
    assert not hasattr(ops, "_resolve")


def test_register_op_rejects_duplicate_names():
    """Op names are global: re-registering must fail loudly, not silently
    clobber another module's spec."""
    with pytest.raises(ValueError, match="already registered"):
        ops.register_op("selective_scan_step", ("ref",))
    # the original spec survives the failed attempt
    assert ops.resolve_impl("selective_scan_step", "pallas") == "fused"


# ---------------------------------------------------------------------------
# decode-step kernel vs oracle (dtype sweep)
# ---------------------------------------------------------------------------

def _step_inputs(key, B, De, N, dtype):
    ks = jax.random.split(key, 7)
    h = jax.random.normal(ks[0], (B, De, N), jnp.float32)
    u = jax.random.normal(ks[1], (B, De)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[2], (B, De)) - 1.0).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[3], (De, N)) * 0.5)
    Bt = jax.random.normal(ks[4], (B, N)).astype(dtype)
    Ct = jax.random.normal(ks[5], (B, N)).astype(dtype)
    D = jnp.ones((De,), jnp.float32) * 0.5
    return h, u, dt, A, Bt, Ct, D


@pytest.mark.parametrize("B,De,N,de_tile", [
    (1, 8, 4, 8), (3, 16, 4, 8), (2, 32, 16, 32), (2, 24, 8, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_step_pallas_vs_ref(B, De, N, de_tile, dtype):
    h, u, dt, A, Bt, Ct, D = _step_inputs(jax.random.PRNGKey(0), B, De, N,
                                          dtype)
    h_ref, y_ref = ref.selective_scan_step(h, u, dt, A, Bt, Ct, D)
    h_pal, y_pal = decode_step_pallas(h, u, dt, A, Bt, Ct, D,
                                      de_tile=de_tile, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(h_pal), np.asarray(h_ref),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,De,N,Dm,de_tile", [
    (2, 16, 4, 8, 16),
    (2, 32, 8, 16, 8),     # multi-tile: out row accumulates across De tiles
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_step_fused_epilogue_vs_ref(B, De, N, Dm, de_tile, dtype):
    h, u, dt, A, Bt, Ct, D = _step_inputs(jax.random.PRNGKey(1), B, De, N,
                                          dtype)
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    g = jax.random.normal(ks[0], (B, De)).astype(dtype)
    w_out = (jax.random.normal(ks[1], (De, Dm)) * 0.1).astype(dtype)
    h_ref, y_ref = ref.selective_scan_step(h, u, dt, A, Bt, Ct, D)
    out_ref = dense(y_ref * g, w_out)
    h_pal, out_pal = decode_step_fused_pallas(h, u, dt, A, Bt, Ct, D, g,
                                              w_out, de_tile=de_tile,
                                              interpret=True)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(h_pal), np.asarray(h_ref),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(out_pal, np.float32),
                               np.asarray(out_ref, np.float32),
                               atol=tol, rtol=tol)


def test_decode_step_without_skip_term():
    h, u, dt, A, Bt, Ct, _ = _step_inputs(jax.random.PRNGKey(3), 2, 8, 4,
                                          jnp.float32)
    h_ref, y_ref = ref.selective_scan_step(h, u, dt, A, Bt, Ct, None)
    h_pal, y_pal = decode_step_pallas(h, u, dt, A, Bt, Ct, None,
                                      interpret=True)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-6, rtol=1e-6)


def test_ops_step_requires_gate_and_wout_together():
    h, u, dt, A, Bt, Ct, D = _step_inputs(jax.random.PRNGKey(4), 1, 8, 4,
                                          jnp.float32)
    with pytest.raises(ValueError):
        ops.selective_scan_step(h, u, dt, A, Bt, Ct, D,
                                gate=jnp.ones((1, 8)))


def test_ops_step_ref_matches_legacy_composition():
    """impl='ref' with the epilogue must equal the legacy unfused op order
    bit-for-bit (this is what keeps kernels=None byte-stable)."""
    h, u, dt, A, Bt, Ct, D = _step_inputs(jax.random.PRNGKey(5), 2, 16, 4,
                                          jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(6), (2, 16))
    w_out = jax.random.normal(jax.random.PRNGKey(7), (16, 8)) * 0.1
    h_ref, y = ref.selective_scan_step(h, u, dt, A, Bt, Ct, D)
    legacy = dense(y * g, w_out)
    h2, out = ops.selective_scan_step(h, u, dt, A, Bt, Ct, D, gate=g,
                                      w_out=w_out, impl="ref")
    assert np.array_equal(np.asarray(out), np.asarray(legacy))
    assert np.array_equal(np.asarray(h2), np.asarray(h_ref))


# ---------------------------------------------------------------------------
# routed expert projection vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,D,E,F,K", [
    (4, 16, 4, 24, 2), (8, 32, 8, 16, 1), (2, 8, 2, 8, 2), (5, 24, 3, 40, 2),
])
@pytest.mark.parametrize("weighted", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_routed_matmul_impls_vs_ref(T, D, E, F, K, weighted, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (T, D)).astype(dtype)
    w = (jax.random.normal(ks[1], (E, D, F)) * 0.1).astype(dtype)
    idx = jax.random.randint(ks[2], (T, K), 0, E)
    wts = (jax.nn.softmax(jax.random.normal(ks[3], (T, K)), axis=-1)
           if weighted else None)
    y_ref = ref.routed_matmul_ref(x, w, idx, wts)
    y_fus = ref.routed_matmul_fused(x, w, idx, wts)
    y_pal = routed_matmul_pallas(x, w, idx, wts, interpret=True)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    for got in (y_fus, y_pal):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   atol=tol, rtol=tol)


def test_routed_matmul_ref_matches_dense_moe_linear():
    """The op's ref oracle and the dispatch layer's dense path are the same
    float composition — one correctness gate for both."""
    from repro.core import moe_dispatch as md
    from repro.core.router import Routing
    T, D, E, F, K = 6, 8, 4, 12, 2
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(ks[0], (T, D))
    w = jax.random.normal(ks[1], (E, D, F)) * 0.1
    idx = jax.random.randint(ks[2], (T, K), 0, E)
    wts = jax.nn.softmax(jax.random.normal(ks[3], (T, K)), axis=-1)
    routing = Routing(num_experts=E, top_k=K, weights=wts[None],
                      expert_idx=idx[None], probs=None, metrics={})
    y_dense = md.dense_moe_linear(routing, x[None], w, weighted=True)[0]
    y_op = ref.routed_matmul_ref(x, w, idx, wts)
    assert np.array_equal(np.asarray(y_dense), np.asarray(y_op))


# ---------------------------------------------------------------------------
# one decode step through every mixer pattern: ref vs pallas scope
# ---------------------------------------------------------------------------

# the identity harness's sweep, extended with every rom_* family (this
# module exercises the routed-matmul decode fast path per family)
from identity import PATTERNS as _BASE_PATTERNS  # noqa: E402

PATTERNS = _BASE_PATTERNS + [("rom_mamba2",), ("rom_gdn",), ("rom_rglru",),
                             ("rom_mlstm",)]


@pytest.mark.parametrize("pattern", PATTERNS,
                         ids=["+".join(p) for p in PATTERNS])
def test_decode_step_scope_identity_all_patterns(pattern):
    """One jitted lm.decode_step under default_impl('ref') vs ('pallas'):
    non-RoM patterns share the exact oracle graph (bitwise-equal logits);
    RoM patterns swap the O(E×) dense mix for the top-k gathered fast path,
    allowed ULP-level float drift but never an argmax change here."""
    cfg = _full_cfg(((pattern, 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    st = lm.init_state(cfg, 2, 16, jnp.dtype(cfg.dtype))
    toks = jnp.asarray([[3], [7]], jnp.int32)
    rt = lm.Runtime(shard=ParallelPlan.single_device().shard_ctx(),
                    rng=None, train=False)

    def f(p, s, t):
        return lm.decode_step(p, s, t, jnp.int32(0), cfg, rt)

    outs = {}
    for impl in ("ref", "pallas"):
        with ops.default_impl(impl):
            logits, _ = jax.jit(f)(params, st, toks)
        outs[impl] = np.asarray(logits)
    if pattern[0].startswith("rom_"):
        np.testing.assert_allclose(outs["pallas"], outs["ref"], atol=1e-6,
                                   rtol=1e-6)
        assert np.array_equal(outs["pallas"].argmax(-1),
                              outs["ref"].argmax(-1))
    else:
        assert np.array_equal(outs["pallas"], outs["ref"])


# ---------------------------------------------------------------------------
# engine-level greedy identity: kernels="pallas" vs "ref"
# ---------------------------------------------------------------------------

def _engine_tokens(cfg, params, kernels, *, admission="interleaved",
                   speculative=0, cache=None, scheduler=None):
    eng = ServeEngine(cfg, params,
                      engine=EngineConfig(max_slots=2, max_len=48, seed=0,
                                          max_prefill_chunk=8,
                                          admission=admission,
                                          speculative=speculative,
                                          kernels=kernels),
                      prefix_cache=cache, scheduler=scheduler)
    rng = np.random.default_rng(3)
    reqs = [Request(id=i,
                    prompt=rng.integers(2, cfg.vocab_size,
                                        size=(n,)).tolist(),
                    max_new_tokens=6)
            for i, n in enumerate([5, 11, 3, 7])]
    res = eng.run(reqs)
    return {r.id: (r.tokens, r.finish_reason) for r in res}


@pytest.mark.parametrize("pattern", [("mamba", "attn"), ("rom_mamba", "mlp")],
                         ids=["mamba+attn", "rom_mamba+mlp"])
@pytest.mark.parametrize("mode", ["interleaved", "sequential", "speculative"])
def test_engine_greedy_identity_pallas_vs_ref(pattern, mode):
    """EngineConfig(kernels='pallas') must emit greedy tokens identical to
    kernels='ref' through interleaved, sequential, and speculative serving
    (4 mixed-length requests on 2 slots force admission mid-decode)."""
    cfg = _full_cfg(((pattern, 2),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    kw = (dict(speculative=3) if mode == "speculative"
          else dict(admission=mode))
    a = _engine_tokens(cfg, params, "ref", **kw)
    b = _engine_tokens(cfg, params, "pallas", **kw)
    assert a == b


def test_engine_greedy_identity_with_prefix_cache_hits():
    """Cache-hit admission (restored prefix snapshots, grouped lanes) under
    kernels='pallas' vs 'ref': same greedy tokens, and the cache must
    actually serve hits in both runs."""
    from repro.serve import CachedSuffixFirst
    cfg = _full_cfg((((("rom_mamba", "mlp")), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    shared = rng.integers(2, cfg.vocab_size, size=(12,)).tolist()
    outs = {}
    for impl in ("ref", "pallas"):
        cache = PrefixCache(budget_mb=8.0)
        eng = ServeEngine(cfg, params,
                          engine=EngineConfig(max_slots=2, max_len=48,
                                              seed=0, max_prefill_chunk=4,
                                              kernels=impl),
                          prefix_cache=cache,
                          scheduler=CachedSuffixFirst(cache))
        eng.run([Request(id=-1, prompt=shared + [1], max_new_tokens=1)])
        reqs = [Request(id=i, prompt=shared + [40 + i], max_new_tokens=6)
                for i in range(3)]
        res = eng.run(reqs)
        assert eng.stats["cache_hit_tokens"] > 0, impl
        outs[impl] = {r.id: r.tokens for r in res}
    assert outs["ref"] == outs["pallas"]


def test_engine_config_rejects_unknown_kernels():
    cfg = _full_cfg(((("mamba",), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError):
        ServeEngine(cfg, params, engine=EngineConfig(kernels="cuda"))
