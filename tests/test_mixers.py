"""Token-mixer equivalences: full-sequence vs single-token decode steps,
and chunked-parallel vs sequential forms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (GDNConfig, Mamba2Config, MambaConfig,
                                ModelConfig, RGLRUConfig, XLSTMConfig)
from repro.distributed.sharding import ShardCtx
from repro.nn import rglru as rgl
from repro.nn import ssm
from repro.nn import xlstm as xl
from repro.nn.layers import Runtime

RT = Runtime(shard=ShardCtx())


def _cfg(**kw):
    base = dict(name="t", d_model=32, vocab_size=64,
                segments=((("mamba",), 1),),
                mamba=MambaConfig(d_state=4, chunk=8),
                mamba2=Mamba2Config(d_state=8, head_dim=16, chunk=8),
                gdn=GDNConfig(num_heads=2, head_dim=8),
                rglru=RGLRUConfig(num_heads=2),
                xlstm=XLSTMConfig(num_heads=2, chunk=8),
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


MIX = [
    ("mamba", ssm.mamba_init, ssm.mamba_apply, ssm.mamba_init_state,
     ssm.mamba_step, 1e-4),
    ("mamba2", ssm.mamba2_init, ssm.mamba2_apply, ssm.mamba2_init_state,
     ssm.mamba2_step, 5e-4),
    ("gdn", ssm.gdn_init, ssm.gdn_apply, ssm.gdn_init_state, ssm.gdn_step,
     5e-4),
    ("rglru", rgl.rglru_init, rgl.rglru_apply, rgl.rglru_init_state,
     rgl.rglru_step, 1e-4),
    ("mlstm", xl.mlstm_init, xl.mlstm_apply, xl.mlstm_init_state,
     xl.mlstm_step, 5e-4),
    ("slstm", xl.slstm_init, xl.slstm_apply, xl.slstm_init_state,
     xl.slstm_step, 1e-4),
]


@pytest.mark.parametrize("name,init,apply,init_state,step,tol", MIX)
def test_step_matches_sequence(name, init, apply, init_state, step, tol):
    cfg = _cfg()
    B, S = 2, 16
    params = init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32)) * 0.5
    y_full, _ = apply(params, x, cfg, RT)
    st = init_state(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        y, st, _ = step(params, x[:, t:t + 1], st, jnp.int32(t), cfg, RT)
        outs.append(y)
    y_steps = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_steps), np.asarray(y_full),
                               atol=tol, rtol=tol)


def test_mlstm_chunked_matches_sequential():
    cfg = _cfg()
    params = xl.mlstm_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32)) * 0.5
    h = x @ params["w_in"]
    z = x @ params["w_gate"]
    y_seq = xl.mlstm_core(params, h, z, cfg, RT, chunked=False)
    y_chk = xl.mlstm_core(params, h, z, cfg, RT, chunked=True)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_chk),
                               atol=2e-4, rtol=2e-4)


def test_ssd_chunk_invariance():
    """Mamba-2 SSD output must not depend on the chunk size."""
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    B, S, H, P, N = 2, 64, 2, 8, 8
    x = jax.random.normal(ks[0], (B, S, H, P))
    a = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    Bm = jax.random.normal(ks[2], (B, S, N))
    Cm = jax.random.normal(ks[3], (B, S, N))
    y8 = ssm.ssd_chunked(x, a, Bm, Cm, 8)
    y16 = ssm.ssd_chunked(x, a, Bm, Cm, 16)
    y64 = ssm.ssd_chunked(x, a, Bm, Cm, 64)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16), atol=1e-3,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y64), atol=1e-3,
                               rtol=1e-3)


def test_selective_scan_chunk_invariance():
    from repro.kernels import ref
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, De, N = 2, 64, 8, 4
    u = jax.random.normal(ks[0], (B, S, De))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, De)))
    A = -jnp.exp(jax.random.normal(ks[2], (De, N)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y8 = ref.selective_scan_ref(u, dt, A, Bm, Cm, chunk=8)
    y32 = ref.selective_scan_ref(u, dt, A, Bm, Cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), atol=1e-4,
                               rtol=1e-4)


def test_rglru_stability():
    """RG-LRU is a contraction: bounded inputs give bounded states at long S."""
    cfg = _cfg()
    params = rgl.rglru_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 512, 32))
    y, _ = rgl.rglru_apply(params, x, cfg, RT)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(jnp.abs(y).max()) < 1e3
