"""Trajectory gate semantics (benchmarks/trajectory.py): threshold
classes, identity gates, and — the regression this file pins — a baseline
scenario missing from the current report must fail the gate loudly, not
silently pass through the key intersection."""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "benchmarks"))
import trajectory  # noqa: E402


def _report(scenarios, schema=1):
    return {"schema_version": schema, "scenarios": scenarios}


def _write(tmp_path, name, report):
    p = tmp_path / name
    p.write_text(json.dumps(report))
    return str(p)


BASE = {
    "decode": {"decode_tps": 100.0, "wall_s": 2.0},
    "expert_library": {"decode_tps": 80.0, "greedy_identical": True},
}


def test_green_when_reports_match(tmp_path):
    b = _write(tmp_path, "base.json", _report(BASE))
    c = _write(tmp_path, "cur.json", _report(BASE))
    assert trajectory.main(["--baseline", b, "--current", c]) == 0


def test_missing_scenario_fails_loudly(tmp_path, capsys):
    """A scenario present in the committed baseline but absent from the
    fresh report (renamed / crashed / filtered out) must fail the gate
    with a message naming it — previously the key intersection silently
    passed."""
    b = _write(tmp_path, "base.json", _report(BASE))
    cur = {"decode": BASE["decode"]}            # expert_library vanished
    c = _write(tmp_path, "cur.json", _report(cur))
    assert trajectory.main(["--baseline", b, "--current", c]) == 1
    out = capsys.readouterr().out
    assert "MISSING SCENARIO" in out
    assert "expert_library" in out


def test_missing_scenarios_helper_ignores_extra_current():
    """New scenarios in the current report are fine (the next --update
    adopts them); only baseline scenarios can go missing."""
    extra = dict(BASE, brand_new={"decode_tps": 5.0})
    assert trajectory.missing_scenarios(_report(BASE), _report(extra)) == []
    assert trajectory.missing_scenarios(
        _report(extra), _report(BASE)) == ["brand_new"]
    # non-dict scenario values (stray counters) are not scenarios
    weird = dict(BASE, n_runs=3)
    assert trajectory.missing_scenarios(_report(weird), _report(BASE)) == []


def test_throughput_regression_still_fails(tmp_path):
    cur = {"decode": {"decode_tps": 50.0, "wall_s": 2.0},
           "expert_library": BASE["expert_library"]}
    b = _write(tmp_path, "base.json", _report(BASE))
    c = _write(tmp_path, "cur.json", _report(cur))
    assert trajectory.main(["--baseline", b, "--current", c]) == 1


def test_identity_gate_is_hard(tmp_path):
    cur = {"decode": BASE["decode"],
           "expert_library": {"decode_tps": 80.0, "greedy_identical": False}}
    b = _write(tmp_path, "base.json", _report(BASE))
    c = _write(tmp_path, "cur.json", _report(cur))
    assert trajectory.main(["--baseline", b, "--current", c]) == 1
    assert trajectory.main(["--identity-only", "--current", c]) == 1
    ok = _write(tmp_path, "ok.json", _report(BASE))
    assert trajectory.main(["--identity-only", "--current", ok]) == 0


def test_schema_change_skips_metric_gates(tmp_path):
    """A schema bump skips metric gating (fresh baseline required) — and
    also the missing-scenario gate, which compares across the bump."""
    b = _write(tmp_path, "base.json", _report(BASE, schema=1))
    c = _write(tmp_path, "cur.json", _report({"decode": BASE["decode"]},
                                             schema=2))
    assert trajectory.main(["--baseline", b, "--current", c]) == 0
