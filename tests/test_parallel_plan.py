"""ParallelPlan: mesh-aware serving.

Light tests (single device): plan construction/parsing, serving-rule
resolution, lane-width padding, host-mesh validation, EngineConfig
folding, the cache snapshot grain, and the benchmark plan stamp.

Sharded-serving parity (slow, subprocess with a forced 8-device host
platform): greedy tokens from a ``data=4`` plan — and ``data=2,model=2``
with the expert partition for ``rom_mamba`` — must be **bit-identical**
to ``ParallelPlan.single_device()`` per mixer pattern, composing with the
prefix cache, speculative decoding and interleaved admission.  CI runs
these in the dedicated 8-virtual-device job (see .github/workflows/ci.yml).
"""
import pytest

from repro.distributed.plan import ParallelPlan


# ---------------------------------------------------------------------------
# plan construction (single device — no mesh required)
# ---------------------------------------------------------------------------

def test_single_device_plan_is_inert():
    plan = ParallelPlan.single_device()
    assert plan.mesh is None
    assert plan.data_size == 1 and plan.expert_size == 1
    assert plan.replicated() is None
    assert plan.place_params({"x": 1}) == {"x": 1}
    assert plan.shard_ctx().mesh is None
    d = plan.describe()
    assert d["mesh"] is None
    assert d["slot_partition"] is None and d["expert_partition"] is None


def test_parse_specs():
    assert ParallelPlan.parse("").mesh is None
    assert ParallelPlan.parse(None).mesh is None
    assert ParallelPlan.parse("single").mesh is None
    for bad in ("data=x", "slots=4", "data", "data=4;model=2"):
        with pytest.raises(ValueError):
            ParallelPlan.parse(bad)


def test_parse_one_device_mesh_drops_partitions():
    # on a 1-device host, data=1 builds a (1,1) mesh: partitions of size 1
    # are dropped to None so shardings degenerate to replicated
    plan = ParallelPlan.parse("data=1,model=1")
    assert plan.mesh is not None
    assert plan.slot_axis is None and plan.expert_axis is None
    assert plan.data_size == 1


def test_lane_width_pads_to_pow2_and_slot_partition():
    import dataclasses

    single = ParallelPlan.single_device()
    assert [single.lane_width(n) for n in (1, 2, 3, 5)] == [1, 2, 4, 8]
    assert single.round_slots(3) == 3

    class _FakeMesh:           # lane_width/round_slots only read .shape
        shape = {"data": 4, "model": 1}

    plan4 = dataclasses.replace(single, mesh=_FakeMesh(), slot_axis="data")
    assert plan4.data_size == 4
    # pow2 first, then up to a multiple of the data-axis size
    assert [plan4.lane_width(n) for n in (1, 3, 4, 5, 6)] == [4, 4, 4, 8, 8]
    assert [plan4.round_slots(n) for n in (1, 4, 6)] == [4, 4, 8]

    class _FakeMesh3:
        shape = {"data": 3, "model": 1}

    plan3 = dataclasses.replace(single, mesh=_FakeMesh3(), slot_axis="data")
    assert plan3.lane_width(2) == 3 and plan3.round_slots(7) == 9


def test_serving_rules_replicate_params_and_partition_experts():
    from repro.distributed.plan import serving_rules
    rd = serving_rules(None, "data", "model").as_dict()
    assert rd["embed"] == (None,) and rd["inner"] == (None,)
    assert rd["experts"] == ("model", None)
    assert rd["experts_ep"] == ("model", None)
    assert rd["act_experts"] == ("model", None)
    assert rd["act_batch"] == ("data", None)
    # partitions can be disabled independently
    rd = serving_rules(None, None, None).as_dict()
    assert rd["experts"] == (None,) and rd["act_batch"] == (None,)


def test_make_host_mesh_validates_shape():
    from repro.launch.mesh import make_host_mesh
    m = make_host_mesh()                      # default: all devices on data
    assert tuple(m.shape.keys()) == ("data", "model")
    with pytest.raises(ValueError):
        make_host_mesh((3, 5))                # 15 devices on a 1-dev host
    with pytest.raises(ValueError):
        make_host_mesh((0, 1))
    with pytest.raises(ValueError):
        make_host_mesh((1, 1, 1))


def test_engine_rejects_mesh_kwarg_and_unknown_knobs():
    from repro.serve import EngineConfig, ServeEngine
    from repro.configs.base import MambaConfig, ModelConfig
    cfg = ModelConfig(name="t", d_model=16, vocab_size=32,
                      segments=((("mamba",), 1),),
                      mamba=MambaConfig(d_state=4, chunk=8),
                      dtype="float32")
    from repro.models import lm
    import jax
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(TypeError):
        ServeEngine(cfg, params, mesh=None)
    with pytest.raises(TypeError):
        ServeEngine(cfg, params, rules=None)
    with pytest.raises(TypeError):
        ServeEngine(cfg, params, bogus=3)
    # keyword knobs override EngineConfig fields
    eng = ServeEngine(cfg, params, engine=EngineConfig(max_slots=2),
                      max_len=32)
    assert eng.max_slots == 2 and eng.max_len == 32
    assert eng.engine_config == EngineConfig(max_slots=2, max_len=32)
    assert eng.plan.mesh is None              # single-device default


def test_cache_grain_bounds_published_boundaries():
    from repro.serve import PrefixCache
    cache = PrefixCache(budget_mb=1.0, grain=4)
    calls = []

    def snap(p):
        return lambda: (calls.append(p) or {"h": __import__("numpy").zeros(2)})

    assert not cache.insert(tuple(range(6)), snap(6))     # 6 % 4 != 0
    assert cache.stats["grain_skips"] == 1
    assert calls == []                                    # no device copy
    assert cache.insert(tuple(range(8)), snap(8))
    assert cache.insert(tuple(range(4)), snap(4))
    assert not cache.insert(tuple(range(7)), snap(7))
    assert len(cache) == 2
    assert cache.peek_len(tuple(range(8)) + (99,)) == 8
    assert cache.summary()["grain"] == 4
    with pytest.raises(ValueError):
        PrefixCache(grain=0)


def test_engine_stamp_records_plan_and_grain():
    import sys
    import pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "benchmarks"))
    try:
        import serving as bench
    finally:
        sys.path.pop(0)
    import jax
    from repro.configs.base import MambaConfig, ModelConfig
    from repro.models import lm
    from repro.serve import PrefixCache, ServeEngine
    cfg = ModelConfig(name="t", d_model=16, vocab_size=32,
                      segments=((("mamba",), 1),),
                      mamba=MambaConfig(d_state=4, chunk=8),
                      dtype="float32")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_slots=2, max_len=16,
                      prefix_cache=PrefixCache(budget_mb=1.0, grain=8))
    stamp = bench.engine_stamp(eng)
    assert stamp["plan"] == {"mesh": None, "slot_partition": None,
                             "expert_partition": None}
    assert stamp["cache_grain"] == 8
    assert stamp["schema_version"] == bench.SCHEMA_VERSION


# ---------------------------------------------------------------------------
# sharded-serving parity (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------

_COMMON = """
import jax, numpy as np
from repro.configs.base import (AttentionConfig, GDNConfig, Mamba2Config,
                                MambaConfig, ModelConfig, RGLRUConfig,
                                RoMConfig, XLSTMConfig)
from repro.distributed.plan import ParallelPlan
from repro.models import lm
from repro.serve import EngineConfig, Request, ServeEngine

def full_cfg(segments, **kw):
    base = dict(name="t", d_model=32, vocab_size=64, segments=segments,
                d_ff=64,
                mamba=MambaConfig(d_state=4, chunk=8),
                mamba2=Mamba2Config(d_state=8, head_dim=16, chunk=8),
                gdn=GDNConfig(num_heads=2, head_dim=8),
                rglru=RGLRUConfig(num_heads=2),
                xlstm=XLSTMConfig(num_heads=2, chunk=8),
                attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                          head_dim=8),
                rom=RoMConfig(num_experts=4, top_k=2, jitter_eps=0.0,
                              capacity_factor=8.0, impl="capacity"),
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)

def requests(cfg, lens, gen=5, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(id=i,
                    prompt=rng.integers(2, cfg.vocab_size,
                                        size=(n,)).tolist(),
                    max_new_tokens=gen)
            for i, n in enumerate(lens)]

def run(cfg, params, plan, ec, reqs, **engine_kw):
    eng = ServeEngine(cfg, params, plan=plan, engine=ec, **engine_kw)
    res = {r.id: (r.tokens, r.finish_reason) for r in eng.run(reqs)}
    return eng, res
"""

PATTERNS = [("mamba", "attn"), ("mamba2",), ("gdn",), ("rglru",),
            ("mlstm",), ("slstm",), ("rom_mamba", "mlp")]


@pytest.mark.slow
@pytest.mark.parametrize("pattern", PATTERNS,
                         ids=["+".join(p) for p in PATTERNS])
def test_sharded_plan_greedy_bit_identical(subproc, pattern):
    """data=4 plan == single_device, bit-identical greedy tokens, for every
    mixer pattern — with the prefix cache and speculative decoding enabled
    on the sharded engine (half the requests share a prefix so cache
    restores actually happen); rom_mamba additionally under
    data=2,model=2 (the expert partition routes tokens to expert
    shards)."""
    plans = 'plans = [ParallelPlan.host(data=4)]'
    if "rom_mamba" in pattern:
        plans += '\nplans.append(ParallelPlan.host(data=2, model=2))'
    subproc(_COMMON + f"""
from repro.serve import CachedSuffixFirst, PrefixCache
cfg = full_cfg((({pattern!r}, 1),))
params = lm.init_params(jax.random.PRNGKey(0), cfg)
ec = EngineConfig(max_slots=4, max_len=32, seed=0, max_prefill_chunk=8)
spec_ec = EngineConfig(max_slots=4, max_len=32, seed=0, max_prefill_chunk=8,
                       speculative=2, draft_stride=2)
shared = list(range(4, 12))                 # 8-token shared prefix
def reqs():
    rng = np.random.default_rng(3)
    lens = [5, 11, 3, 7, 4, 6]
    out = []
    for i, n in enumerate(lens):
        p = rng.integers(2, cfg.vocab_size, size=(n,)).tolist()
        if i % 2 == 0:
            p = shared + p[:3]              # half the batch shares a prefix
        out.append(Request(id=i, prompt=p, max_new_tokens=5))
    return out
_, ref = run(cfg, params, ParallelPlan.single_device(), ec, reqs())
{plans}
for plan in plans:
    cache = PrefixCache(budget_mb=16.0)
    eng, got = run(cfg, params, plan, spec_ec, reqs(),
                   prefix_cache=cache, scheduler=CachedSuffixFirst(cache))
    leaf = jax.tree_util.tree_leaves(eng.store.state)[0]
    # the canonical state's slot axis is actually sharded over the plan's
    # slot partition (leading spec entry; other axes replicate)
    assert leaf.sharding.spec[0] == plan.slot_axis, leaf.sharding
    assert got == ref, (plan.describe(), got, ref)
    assert eng.stats["spec_rounds"] > 0          # speculation actually ran
    assert eng.stats["cache_hit_tokens"] > 0     # cache restores happened
print("sharded parity OK:", {pattern!r})
""", n_devices=8)


@pytest.mark.slow
def test_sharded_plan_composes_with_cache_and_speculative(subproc):
    """data=4 plan + prefix cache + speculative decoding + interleaved
    admission together still emit bit-identical greedy tokens, and the
    warm cache serves hits under the sharded store (host snapshots are
    topology-portable)."""
    subproc(_COMMON + """
from repro.serve import CachedSuffixFirst, PrefixCache
for pattern in [("mamba", "attn"), ("rom_mamba", "mlp")]:
    cfg = full_cfg(((pattern, 2),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    ec = EngineConfig(max_slots=4, max_len=48, seed=0, max_prefill_chunk=8,
                      speculative=3, draft_stride=2)
    shared = list(range(4, 20))              # 16-token shared prefix
    def reqs():
        rng = np.random.default_rng(9)
        return [Request(id=i,
                        prompt=shared + rng.integers(
                            2, cfg.vocab_size, size=(n,)).tolist(),
                        max_new_tokens=5)
                for i, n in enumerate([5, 3, 7, 2, 4, 6])]
    _, ref = run(cfg, params, ParallelPlan.single_device(),
                 EngineConfig(max_slots=4, max_len=48, seed=0,
                              max_prefill_chunk=8), reqs())
    plan = ParallelPlan.host(data=4)
    cache = PrefixCache(budget_mb=32.0, grain=8)
    eng, got = run(cfg, params, plan, ec, reqs(),
                   prefix_cache=cache, scheduler=CachedSuffixFirst(cache))
    assert got == ref, (pattern, got, ref)
    assert eng.stats["spec_rounds"] > 0
    # warm pass: cached prefixes restore into the sharded lane state
    eng2, got2 = run(cfg, params, plan, ec, reqs(),
                     prefix_cache=cache, scheduler=CachedSuffixFirst(cache))
    assert got2 == ref, pattern
    assert eng2.stats["cache_hit_tokens"] > 0
    for p, _n in cache.snapshot_prefixes():
        assert len(p) % 8 == 0               # grain respected
    print("compose OK:", pattern)
""", n_devices=8)


@pytest.mark.slow
def test_sharded_sequential_admission_matches(subproc):
    """admission='sequential' (1-slot lane states replicate, canonical
    state sharded) also matches single-device output under data=4."""
    subproc(_COMMON + """
cfg = full_cfg(((("mamba", "attn"), 1),))
params = lm.init_params(jax.random.PRNGKey(0), cfg)
ec = EngineConfig(max_slots=4, max_len=32, seed=0, max_prefill_chunk=8,
                  admission="sequential")
lens = [5, 11, 3, 7, 4]
_, ref = run(cfg, params, ParallelPlan.single_device(), ec,
             requests(cfg, lens))
_, got = run(cfg, params, ParallelPlan.host(data=4), ec,
             requests(cfg, lens))
assert got == ref
print("sequential sharded OK")
""", n_devices=8)


@pytest.mark.slow
def test_sharded_plan_kernels_pallas_matches_ref(subproc):
    """EngineConfig(kernels='pallas') (fused decode fast path) under a
    data=4 plan — and data=2,model=2 for the RoM pattern, where the routed
    projection takes the top-k gathered path — emits the same greedy
    tokens as kernels='ref' on a single device."""
    subproc(_COMMON + """
for pattern, plans in [
        (("mamba", "attn"), [ParallelPlan.host(data=4)]),
        (("rom_mamba", "mlp"), [ParallelPlan.host(data=4),
                                ParallelPlan.host(data=2, model=2)]),
]:
    cfg = full_cfg(((pattern, 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    lens = [5, 11, 3, 7, 4, 6]
    def ec(kernels):
        return EngineConfig(max_slots=4, max_len=32, seed=0,
                            max_prefill_chunk=8, kernels=kernels)
    _, ref = run(cfg, params, ParallelPlan.single_device(), ec("ref"),
                 requests(cfg, lens))
    for plan in plans:
        _, got = run(cfg, params, plan, ec("pallas"), requests(cfg, lens))
        assert got == ref, (pattern, plan.describe(), got, ref)
    print("sharded kernels parity OK:", pattern)
""", n_devices=8)


@pytest.mark.slow
def test_expert_sharded_grouped_matmul_matches_oracle(subproc):
    """The grouped-matmul path under the plan's expert partition
    (shard_map over the model axis) computes exactly the capacity-einsum
    oracle."""
    subproc("""
import jax, numpy as np
from repro.core import moe_dispatch as md
from repro.core import router as rtr
from repro.distributed.plan import ParallelPlan

plan = ParallelPlan.host(data=2, model=4)
shard = plan.shard_ctx()
G, g, D, F, E, K = 2, 16, 8, 12, 8, 2
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (G, g, D))
w = jax.random.normal(jax.random.fold_in(key, 1), (E, D, F))
wr = jax.random.normal(jax.random.fold_in(key, 2), (D, E)) * 0.1
routing = rtr.route(wr, x, num_experts=E, top_k=K, jitter_eps=0.0,
                    aux_loss_weight=0.0, rng=None, train=False)
dsp = md.make_dispatch(routing, 8.0)
buf = md.dispatch_tokens(dsp, x)
assert md.expert_partition(shard, E) == "model"
assert md.expert_partition(None, E) is None
y_ref = md.expert_matmul(buf, w, dsp.group_sizes, "capacity")
y_s = jax.jit(lambda b, w, gs: md.expert_matmul(
    b, w, gs, "grouped", shard=shard))(buf, w, dsp.group_sizes)
np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_ref),
                           atol=1e-4, rtol=1e-4)
print("expert-sharded grouped == capacity OK")
""", n_devices=8)


@pytest.mark.slow
def test_prefill_lane_width_pads_to_data_axis(subproc):
    """With 6 queued requests on a data=4 plan, the batched prefill job's
    lane width pads past the power of two to a multiple of the data axis."""
    subproc(_COMMON + """
cfg = full_cfg(((("mamba",), 1),))
params = lm.init_params(jax.random.PRNGKey(0), cfg)
plan = ParallelPlan.host(data=4)
eng = ServeEngine(cfg, params, plan=plan,
                  engine=EngineConfig(max_slots=8, max_len=32, seed=0,
                                      max_prefill_chunk=8))
for r in requests(cfg, [5, 5, 5, 5, 5, 5]):
    eng.submit(r)
eng._admit()
assert eng._job is not None and eng._job.width == 8, eng._job.width
assert plan.lane_width(6) == 8 and plan.lane_width(1) == 4
# indivisible max_slots is rejected loudly
try:
    ServeEngine(cfg, params, plan=plan,
                engine=EngineConfig(max_slots=6, max_len=32))
except ValueError as e:
    assert "multiple" in str(e)
else:
    raise AssertionError("max_slots=6 should be rejected on data=4")
while eng.busy():
    eng.tick()
print("lane width OK")
""", n_devices=8)
